"""Benchmark 2 — the rule x attack x regime convergence leaderboard.

The experimental figure every surveyed defence paper reports, extended the
way PR 10 extends the threat model: final training loss under each attack
(static catalogue AND the defense-aware adversaries of
``core.attacks.adaptive``), per rule (including the defenses with memory:
``centered_clip`` and the ``server_momentum`` wrapper), per fault regime:

  ``sync``        — full roster, synchronous timing (train_loop);
  ``stragglers``  — Pareto stragglers + quorum through the async loop;
  ``churn``       — membership churn over a 3-bucket ELASTIC spec (the
                    adaptive attacks recalibrate against each bucket's
                    respecialized spec; the run asserts the bucket compile
                    budget — zero added recompiles per bucket).

Every cell also reports *suspicion accuracy*: the run is recorded with the
PR-6 flight recorder and the per-agent selection-weight telemetry is asked
to finger the Byzantine set (top-f suspicion vs the actual first f agents).
A defense can hold the loss yet fail to identify the attacker (clipping
bounds influence without localizing it) — the leaderboard shows both.

``--smoke`` runs the CI-sized subset; the full grid runs from
``benchmarks/run.py --full``.
"""
from __future__ import annotations

import time

from repro.configs.base import ArchConfig
from repro.core.aggregators import elastic, frac, make_spec, server_momentum
from repro.core.tracecount import TRACE_COUNTS
from repro.data import SyntheticLM
from repro.obs.recorder import Recorder
from repro.obs.telemetry import agent_series, suspicion_scores
from repro.optim import adamw, constant
from repro.simulator import (Churn, Join, SimConfig, Straggler,
                             async_train_loop)
from repro.training import ByzantineConfig, train_loop

CFG = ArchConfig(name="bench", family="dense", num_layers=2, d_model=64,
                 num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                 head_dim=16, dtype="float32")

N, F = 8, 2
BUCKETS = (4, 6, 8)
LR = 3e-3

# attack strengths chosen to actually break the undefended mean
# (scale-1 sign-flip leaves the mean positively aligned)
ATTACK_HYPER = {"sign_flip": {"scale": 4.0}, "alie": {"z": 3.0}}

SMOKE_RULES = ["mean", "trimmed_mean", "centered_clip", "server_momentum"]
FULL_RULES = ["mean", "trimmed_mean", "coordinate_median", "krum",
              "multi_krum", "cge", "phocas", "mda", "bulyan",
              "geometric_median", "median_of_means", "centered_clip",
              "server_momentum"]
SMOKE_ATTACKS = ["none", "sign_flip", "spec_alie", "min_max"]
FULL_ATTACKS = ["none", "sign_flip", "large_value", "alie", "ipm",
                "gaussian", "zero", "spec_alie", "min_max", "slow_drift"]
# the robust-with-memory rules the acceptance gate tracks across regimes
MEMORY_RULES = ("centered_clip", "server_momentum")
ADAPTIVE = ("spec_alie", "min_max", "slow_drift")
# converged-noise floor for the 2x-of-clean gate: once the clean run is
# below this, doubling it is training noise, not an attack succeeding
LOSS_FLOOR = 0.05


def build_spec(rule, n_spec, f_spec):
    hyper = {"tau": 1.0} if rule == "centered_clip" else {}
    if rule == "server_momentum":
        return server_momentum(make_spec("trimmed_mean", f=f_spec, n=n_spec))
    if rule == "bulyan":
        # bulyan needs n >= 4f + 3: at n=8 that caps f at 1
        return make_spec(rule, f=1, n=n_spec)
    return make_spec(rule, f=f_spec, n=n_spec, **hyper)


def _sim(regime, seed=0):
    if regime == "stragglers":
        return SimConfig(faults=(Straggler(dist="pareto", scale=1.0,
                                           prob=0.4, agents=(3, 4)),),
                         quorum=6, max_staleness=3, seed=seed)
    if regime == "churn":
        # at most two agents out at once: the live roster never drops
        # below 6, so with f = frac(1/3) every bucket keeps the two
        # Byzantine agents (always live) at <= f — the defenses are
        # benchmarked inside their tolerance, per bucket
        return SimConfig(faults=(Join(agents=(7,), at=4),
                                 Churn(rate=0.15, mean_out=2.0,
                                       agents=(3, 4)),),
                         seed=seed)
    raise KeyError(regime)


def run_cell(rule, attack, regime, steps):
    """One leaderboard cell: train, record, score.  Returns the cell dict."""
    if regime == "churn":
        spec = build_spec(rule, elastic(N, buckets=BUCKETS),
                          frac(1.0 / 3.0))
    else:
        spec = build_spec(rule, N, F)
    bz = ByzantineConfig(n_agents=N, f=F, aggregator=spec, attack=attack,
                         attack_hyper=dict(ATTACK_HYPER.get(attack, {})))
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=N,
                     per_agent_batch=4)
    rec = Recorder()
    before = TRACE_COUNTS["async_step"]
    t0 = time.perf_counter()
    if regime == "sync":
        # train_loop itself reroutes stateful rules and adaptive attacks
        # through the general async path (synchronous timing, no faults)
        _, hist = train_loop(CFG, bz, adamw(constant(LR)), ds, steps=steps,
                             log_every=steps, log_fn=lambda *_: None,
                             recorder=rec)
        compiles = None
    else:
        _, hist = async_train_loop(CFG, bz, adamw(constant(LR)), ds,
                                   steps=steps, sim=_sim(regime),
                                   log_every=steps, log_fn=lambda *_: None,
                                   recorder=rec)
        compiles = TRACE_COUNTS["async_step"] - before
        if regime == "churn" and compiles > len(BUCKETS):
            raise AssertionError(
                f"{rule}|{attack}|churn: {compiles} compiles over "
                f"{len(BUCKETS)} buckets — elastic budget blown")
    wall = time.perf_counter() - t0
    rec.close()
    susp_acc = None
    if attack != "none":
        ser = agent_series(rec.events, N)
        if ser["sel_w"].shape[0]:
            scores = suspicion_scores(ser["sel_w"], ser["mask"],
                                      ser.get("roster"))
            by_susp = sorted(range(N),
                             key=lambda i: -scores[i]["suspicion"])
            susp_acc = len(set(by_susp[:F]) & set(range(F))) / F
    return {
        "regime": regime, "attack": attack, "rule": rule,
        "final_loss": round(float(hist[-1]["loss"]), 4),
        "suspicion_acc": susp_acc,
        "compiles": compiles,
        "us_per_call": round(wall / steps * 1e6, 1),
    }


def grid(quick: bool = True):
    """The (regime, attack, rule) cells of the leaderboard."""
    rules = SMOKE_RULES if quick else FULL_RULES
    attacks = SMOKE_ATTACKS if quick else FULL_ATTACKS
    cells = [("sync", a, r) for a in attacks for r in rules]
    # fault regimes: the robust subset the acceptance gate tracks (the
    # undefended mean's breakage is established in the sync block)
    fr_rules = [r for r in rules
                if r in ("trimmed_mean",) + MEMORY_RULES]
    fr_attacks = [a for a in attacks if a == "none" or a in ADAPTIVE]
    for regime in ("stragglers", "churn"):
        cells += [(regime, a, r) for a in fr_attacks for r in fr_rules]
    return cells


def run(quick: bool = True):
    """benchmarks/run.py entry point — CSV-shaped rows."""
    steps = 12 if quick else 60
    rows = []
    for regime, attack, rule in grid(quick):
        c = run_cell(rule, attack, regime, steps)
        sa = ("-" if c["suspicion_acc"] is None
              else f"{c['suspicion_acc']:.2f}")
        rows.append({
            "bench": "convergence_leaderboard",
            "name": f"{regime}|{attack}|{rule}",
            "us_per_call": c["us_per_call"],
            "derived": f"final_loss={c['final_loss']:.4f};susp_acc={sa}",
            "cell": c,
        })
    return rows


def check_artifact(data: dict) -> list[str]:
    """The leaderboard's own acceptance gate (also run by CI on the smoke
    artifact).  Returns a list of violations (empty = pass):

      * the undefended mean is broken by every attack it faced (final
        loss >= 2x its clean run in the same regime);
      * every robust-with-memory cell holds final loss within 2x of that
        rule's clean run IN THE SAME REGIME (or within 2x of LOSS_FLOOR
        once the clean run has converged below it), under every attack at
        <= f — including the defense-aware ones, across all three regimes;
      * churn cells stayed inside the elastic bucket compile budget.
    """
    cells = data["rows"]
    by_key = {(c["regime"], c["attack"], c["rule"]): c for c in cells}
    bad = []
    for c in cells:
        clean = by_key.get((c["regime"], "none", c["rule"]))
        if clean is None:
            continue
        if c["rule"] == "mean" and c["attack"] != "none":
            if c["final_loss"] < 2.0 * clean["final_loss"]:
                bad.append(
                    f"undefended mean NOT broken by {c['attack']} in "
                    f"{c['regime']} ({clean['final_loss']} -> "
                    f"{c['final_loss']})")
        if c["rule"] in MEMORY_RULES and c["attack"] != "none":
            if c["final_loss"] > 2.0 * max(clean["final_loss"], LOSS_FLOOR):
                bad.append(
                    f"{c['rule']} degraded by {c['attack']} in "
                    f"{c['regime']}: {clean['final_loss']} -> "
                    f"{c['final_loss']} (beyond 2x clean)")
        if c["regime"] == "churn" and (c["compiles"] or 0) > len(BUCKETS):
            bad.append(f"{c['rule']}|{c['attack']}|churn: compile budget "
                       f"{c['compiles']} > {len(BUCKETS)}")
    return bad


def main(out: str = "BENCH_convergence.json", smoke: bool = False):
    """Standalone artifact: the leaderboard as provenance-stamped JSON
    (``rows`` = one dict per (regime, attack, rule) cell with final loss,
    suspicion accuracy and the churn compile count), the shape the CI
    bench-smoke lane archives and asserts on next to BENCH_serving.json."""
    import json

    cells = [r["cell"] for r in run(quick=smoke)]
    from repro.obs.provenance import provenance
    results = {"bench": "convergence_leaderboard", "smoke": bool(smoke),
               "rows": cells, "provenance": provenance()}
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    for c in cells:
        sa = ("-" if c["suspicion_acc"] is None
              else f"{c['suspicion_acc']:.2f}")
        print(f"{c['regime']:>10s} | {c['attack']:>10s} | "
              f"{c['rule']:<16s} loss={c['final_loss']:.4f} susp={sa}")
    bad = check_artifact(results)
    for b in bad:
        print(f"LEADERBOARD VIOLATION: {b}")
    print(f"wrote {out}")
    if bad:
        raise SystemExit(1)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_convergence.json")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(args.out, args.smoke)
