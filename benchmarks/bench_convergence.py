"""Benchmark 2 — the attack x defence convergence matrix (the experimental
figure every surveyed defence paper reports: final training loss under each
attack, per filter, vs the undefended mean)."""
from __future__ import annotations

import time

from repro.configs.base import ArchConfig
from repro.core.aggregators import make_spec
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.training import ByzantineConfig, train_loop

CFG = ArchConfig(name="bench", family="dense", num_layers=2, d_model=64,
                 num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                 head_dim=16, dtype="float32")


def run(quick: bool = True):
    steps = 40 if quick else 150
    filters = (["mean", "trimmed_mean", "krum", "cge"] if quick else
               ["mean", "trimmed_mean", "coordinate_median", "krum",
                "multi_krum", "geometric_median", "median_of_means", "cge",
                "cgc", "phocas", "bulyan", "mda"])
    # attack strengths chosen to actually break the undefended mean
    # (scale-1 sign-flip leaves the mean positively aligned)
    hypers = {"sign_flip": {"scale": 4.0}, "alie": {"z": 3.0}}
    attacks = (["sign_flip", "large_value"] if quick else
               ["sign_flip", "large_value", "alie", "ipm", "gaussian",
                "zero"])
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8,
                     per_agent_batch=4)
    rows = []
    for attack in attacks:
        for name in filters:
            bz = ByzantineConfig(n_agents=8, f=2,
                                 aggregator=make_spec(name, f=2, n=8),
                                 attack=attack,
                                 attack_hyper=hypers.get(attack, {}))
            t0 = time.perf_counter()
            _, hist = train_loop(CFG, bz, adamw(constant(3e-3)), ds,
                                 steps=steps, log_fn=lambda *_: None)
            wall = time.perf_counter() - t0
            rows.append({
                "bench": "attack_defence_matrix",
                "name": f"{attack}|{name}",
                "us_per_call": round(wall / steps * 1e6, 1),
                "derived": f"final_loss={hist[-1]['loss']:.4f}",
            })
    return rows


def main(out: str = "BENCH_convergence.json", smoke: bool = False):
    """Standalone artifact: the attack x defence matrix as provenance-
    stamped JSON (rows keyed attack|filter with final losses), the shape
    the CI bench-smoke lane archives next to BENCH_serving.json."""
    import json

    rows = run(quick=smoke)
    grid = []
    for r in rows:
        attack, flt = r["name"].split("|", 1)
        grid.append({"attack": attack, "filter": flt,
                     "us_per_call": r["us_per_call"],
                     "final_loss": float(r["derived"].split("=", 1)[1])})
    from repro.obs.provenance import provenance
    results = {"bench": "attack_defence_matrix", "smoke": bool(smoke),
               "grid": grid, "provenance": provenance()}
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    for g in grid:
        print(f"{g['attack']:>12s} | {g['filter']:<18s} "
              f"loss={g['final_loss']:.4f}")
    print(f"wrote {out}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_convergence.json")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(args.out, args.smoke)
