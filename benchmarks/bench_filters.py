"""Benchmark 1 — survey Table 2: the gradient-filter catalogue.

Sections:

  * the Table-2 summary (per registered aggregator: wall-clock per
    ``spec.aggregate`` call on the default impl, asymptotic complexity
    class, empirical (alpha, f)-resilience flag);
  * the IMPL COMPARISON for the kernel-dispatched rules — gather vs fused
    vs pallas across (n, d) up to the model-scale ``n16_d1048576`` point,
    with a per-rule pallas-vs-gather speedup summary;
  * the MASKED comparison — the imputation-free fused masked kernels
    (quorum mask + staleness weights as traced operands) vs the
    imputed-path reconstruction (materialize the imputed (n, d) stack,
    run the plain kernel — the historical masked path) vs the gather
    reference; the fused path must at least match its imputed ancestor
    at every measured (n, d).

``python benchmarks/bench_filters.py`` writes ``BENCH_filters.json``
(``--full`` widens the grid, ``--smoke`` shrinks it to CI-sized shapes);
``benchmarks/run.py`` (PYTHONPATH=src:.) consumes :func:`run` like every
other bench section.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.aggregators import list_aggregators, make_spec
from repro.core.resilience import estimate_alpha_f
from repro.kernels import pallas_masked_supported, pallas_supported

COMPLEXITY = {
    "krum": "O(n^2 d)", "multi_krum": "O(n^2 d)", "m_krum": "O(m n^2 d)",
    "coordinate_median": "O(n d)", "trimmed_mean": "O(n d)",
    "phocas": "O(n d)", "mean_around_median": "O(n d)",
    "geometric_median": "O(n d log^3 1/eps)",
    "median_of_means": "O(nd + fd log^3 1/eps)",
    "mda": "O(C(n,f) + n^2 d)", "cge": "O(n(log n + d))",
    "cgc": "O((n+f)d + n log n)", "bulyan": "O((n-2f)C + nd)",
    "mean": "O(n d)", "zeno": "O(n d)", "rfa": "O(n d iters)",
    "zeno_pp": "O(n d)",
}

IMPLS = ("gather", "fused", "pallas")


def _best_of(fn, iters, repeats=3):
    """Min-of-repeats mean: each repeat averages ``iters`` calls, the
    minimum is reported (robust against scheduler noise on shared CI
    machines)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6                                    # us


def time_spec(spec, g, state=None, iters=20):
    jitted = jax.jit(lambda x: spec.aggregate(x, state=state))
    jitted(g).block_until_ready()
    return _best_of(lambda: jitted(g).block_until_ready(), iters)


def _rule_f(rule: str, n: int, f: int) -> int:
    fr = min(f, (n - 1) // 2)
    if rule == "bulyan":                 # needs n > 2f (n >= 4f+3 proper)
        fr = min(fr, max((n - 1) // 4, 1))
    return fr


def impl_comparison(ns=(8, 16, 32), ds=(4096, 65536), f=3, iters=20,
                    extra_points=(), extra_iters=3):
    """{rule: {"n{n}_d{d}": {impl: us_per_call}}} for every rule with a
    registered Pallas kernel — the gather/fused/pallas series.
    ``extra_points``: additional (n, d) shapes timed with ``extra_iters``
    (the model-scale n16_d1048576 point rides here)."""
    key = jax.random.PRNGKey(0)
    rules = [r for r in list_aggregators("table2") if pallas_supported(r)]
    points = [(n, d, iters) for n in ns for d in ds]
    points += [(n, d, extra_iters) for n, d in extra_points]
    out = {}
    for rule in rules:
        series = {}
        for n, d, it in points:
            g = jax.random.normal(key, (n, d))
            series[f"n{n}_d{d}"] = {
                impl: round(time_spec(
                    make_spec(rule, f=_rule_f(rule, n, f), impl=impl, n=n),
                    g, iters=it), 1)
                for impl in IMPLS}
        out[rule] = series
    return out


def speedup_summary(comp: dict) -> dict:
    """Per-rule pallas-vs-gather speedup (x) at every measured shape."""
    return {rule: {shape: round(impls["gather"] / max(impls["pallas"],
                                                      1e-9), 2)
                   for shape, impls in series.items()}
            for rule, series in comp.items()}


def _mask_weights(n, keep_drop=3):
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    drop = jax.random.choice(k1, n, shape=(min(keep_drop, n - 1),),
                             replace=False)
    mask = jnp.ones((n,), bool).at[drop].set(False)
    w = jax.random.uniform(k2, (n,), minval=0.3, maxval=1.0)
    return mask, w


def time_masked(fn, g, mask, w, iters):
    jitted = jax.jit(fn)
    jitted(g, mask, w).block_until_ready()
    return _best_of(lambda: jitted(g, mask, w).block_until_ready(), iters)


def masked_comparison(ns=(8, 16), ds=(4096, 65536), f=3, iters=20,
                      extra_points=(), extra_iters=3):
    """Masked/weighted aggregation: the fused imputation-free kernels
    ("pallas") vs the historical impute-then-kernel path
    ("pallas_imputed": materialize the imputed (n, d) stack, run the
    plain pallas rule, scale — exactly the engine's pre-flat-pipeline
    masked path) vs the gather reference."""
    key = jax.random.PRNGKey(1)
    rules = [r for r in list_aggregators("table2")
             if pallas_masked_supported(r)]
    points = [(n, d, iters) for n in ns for d in ds]
    points += [(n, d, extra_iters) for n, d in extra_points]
    out = {}
    for rule in rules:
        series = {}
        for n, d, it in points:
            fr = _rule_f(rule, n, f)
            g = jax.random.normal(key, (n, d))
            mask, w = _mask_weights(n)
            pa = make_spec(rule, f=fr, impl="pallas", n=n)
            ga = make_spec(rule, f=fr, impl="gather", n=n)

            def imputed_path(g, mask, w, _pa=pa):
                mf = mask.astype(jnp.float32)
                wv = w.astype(jnp.float32) * mf
                cnt = jnp.maximum(jnp.sum(mf), 1.0)
                tot = jnp.maximum(jnp.sum(wv), 1e-30)
                mean = jnp.sum(g * (wv / tot)[:, None], axis=0)
                imp = jnp.where(mask[:, None], g, mean[None])
                return _pa.aggregate(imp) * (tot / cnt)

            series[f"n{n}_d{d}"] = {
                "pallas": round(time_masked(
                    lambda g, m, w, _pa=pa: _pa.aggregate(g, mask=m,
                                                          weights=w),
                    g, mask, w, it), 1),
                "pallas_imputed": round(time_masked(
                    imputed_path, g, mask, w, it), 1),
                "gather": round(time_masked(
                    lambda g, m, w, _ga=ga: _ga.aggregate(g, mask=m,
                                                          weights=w),
                    g, mask, w, it), 1),
            }
        out[rule] = series
    return out


def run(quick: bool = True):
    rows = []
    n, f = 16, 3
    ds = [4096] if quick else [4096, 65536]
    key = jax.random.PRNGKey(0)
    names = list_aggregators("table2") + ["zeno_pp"]
    for d in ds:
        g = jax.random.normal(key, (n, d))
        for name in names:
            spec = make_spec(name, f=f, n=n)
            state = None
            if spec.stateful:
                # externally-maintained validation gradient (state protocol)
                state = {"server_grad": jnp.mean(g, axis=0)}
            us = time_spec(spec, g, state=state)
            if spec.stateful:
                resilient = True          # validation-gradient rules
            else:
                _, resilient = estimate_alpha_f(spec, n, f,
                                                trials=8 if quick else 32)
            rows.append({
                "bench": "table2_filters", "name": f"{name}_n{n}_d{d}",
                "us_per_call": round(us, 1),
                "derived": (f"complexity={COMPLEXITY.get(name, '-')};"
                            f"impl={spec.impl};"
                            f"alpha_f_ok={resilient}"),
            })
    # the gather/fused/pallas comparison as CSV rows too
    comp = impl_comparison(ns=(16,), ds=tuple(ds), iters=10)
    for rule, series in comp.items():
        for shape, impls in series.items():
            rows.append({
                "bench": "table2_filters",
                "name": f"{rule}_{shape}_impls",
                "us_per_call": impls["pallas"],
                "derived": (f"gather={impls['gather']};"
                            f"fused={impls['fused']};"
                            f"pallas={impls['pallas']}"),
            })
    return rows


def main(out: str = "BENCH_filters.json", full: bool = False,
         smoke: bool = False):
    if smoke:
        # CI-sized: tiny shapes, 2 iters — exercises every code path
        # (all impls, fused vs imputed masked, speedup summary) end to
        # end so the perf plumbing cannot silently rot
        ns, ds, iters, extra = (8,), (1024,), 2, ()
    elif full:
        ns, ds, iters = (8, 16, 32), (4096, 65536, 262144), 20
        extra = ((16, 1_048_576),)
    else:
        ns, ds, iters = (8, 16), (4096, 65536), 10
        extra = ((16, 1_048_576),)           # model-scale point, few iters
    comp = impl_comparison(ns=ns, ds=ds, iters=iters, extra_points=extra)
    # fused-vs-imputed gaps at small d sit near the timing floor: extra
    # iterations keep the comparison honest on noisy CI machines
    masked = masked_comparison(ns=ns, ds=ds,
                               iters=iters if smoke else max(iters, 20),
                               extra_points=extra)
    from repro.obs.provenance import provenance
    payload = {"bench": "filters_impl_comparison",
               "provenance": provenance(),
               "unit": "us_per_call",
               "impls": list(IMPLS),
               "rules": comp,
               "masked_impls": ["pallas", "pallas_imputed", "gather"],
               "masked": masked,
               "speedup_pallas_vs_gather": speedup_summary(comp)}
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
    for rule, series in comp.items():
        for shape, impls in series.items():
            print(f"{rule:20s} {shape:12s} " + "  ".join(
                f"{i}={impls[i]:9.1f}us" for i in IMPLS))
    print("-- masked (fused kernel vs imputed path vs gather) --")
    for rule, series in masked.items():
        for shape, impls in series.items():
            print(f"{rule:20s} {shape:12s} " + "  ".join(
                f"{i}={impls[i]:9.1f}us" for i in impls))
    print("-- pallas vs gather speedup --")
    for rule, series in speedup_summary(comp).items():
        line = "  ".join(f"{shape}={x:6.2f}x" for shape, x in
                         series.items())
        print(f"{rule:20s} {line}")
    print(f"wrote {out}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_filters.json")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(args.out, full=args.full, smoke=args.smoke)
