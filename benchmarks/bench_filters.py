"""Benchmark 1 — survey Table 2: the gradient-filter catalogue.

Per registered aggregator: wall-clock per ``spec.aggregate`` call (jitted,
CPU, fused impl — the path training runs) across (n, d), the asymptotic
complexity class from Table 2, and the empirical (alpha, f)-resilience flag
(§3.5).  Mirrors the survey's summary table with measured numbers; every
rule is reached through the unified :class:`AggregatorSpec` API."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.aggregators import list_aggregators, make_spec
from repro.core.resilience import estimate_alpha_f

COMPLEXITY = {
    "krum": "O(n^2 d)", "multi_krum": "O(n^2 d)", "m_krum": "O(m n^2 d)",
    "coordinate_median": "O(n d)", "trimmed_mean": "O(n d)",
    "phocas": "O(n d)", "mean_around_median": "O(n d)",
    "geometric_median": "O(n d log^3 1/eps)",
    "median_of_means": "O(nd + fd log^3 1/eps)",
    "mda": "O(C(n,f) + n^2 d)", "cge": "O(n(log n + d))",
    "cgc": "O((n+f)d + n log n)", "bulyan": "O((n-2f)C + nd)",
    "mean": "O(n d)", "zeno": "O(n d)", "rfa": "O(n d iters)",
    "zeno_pp": "O(n d)",
}


def time_spec(spec, g, state=None, iters=20):
    jitted = jax.jit(lambda x: spec.aggregate(x, state=state))
    jitted(g).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        jitted(g).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6      # us


def run(quick: bool = True):
    rows = []
    n, f = 16, 3
    ds = [4096] if quick else [4096, 65536]
    key = jax.random.PRNGKey(0)
    names = list_aggregators("table2") + ["zeno_pp"]
    for d in ds:
        g = jax.random.normal(key, (n, d))
        for name in names:
            spec = make_spec(name, f=f, n=n)
            state = None
            if spec.stateful:
                # externally-maintained validation gradient (state protocol)
                state = {"server_grad": jnp.mean(g, axis=0)}
            us = time_spec(spec, g, state=state)
            if spec.stateful:
                resilient = True          # validation-gradient rules
            else:
                _, resilient = estimate_alpha_f(spec, n, f,
                                                trials=8 if quick else 32)
            rows.append({
                "bench": "table2_filters", "name": f"{name}_n{n}_d{d}",
                "us_per_call": round(us, 1),
                "derived": (f"complexity={COMPLEXITY.get(name, '-')};"
                            f"alpha_f_ok={resilient}"),
            })
    return rows
