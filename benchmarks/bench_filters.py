"""Benchmark 1 — survey Table 2: the gradient-filter catalogue.

Two sections:

  * the Table-2 summary (per registered aggregator: wall-clock per
    ``spec.aggregate`` call on the default impl, asymptotic complexity
    class, empirical (alpha, f)-resilience flag);
  * the IMPL COMPARISON for the kernel-dispatched rules — gather vs fused
    vs pallas across (n, d), the series the perf trajectory tracks now
    that ``make_spec`` auto-selects the Pallas path.

``python benchmarks/bench_filters.py`` writes ``BENCH_filters.json``;
``benchmarks/run.py`` (PYTHONPATH=src:.) consumes :func:`run` like every
other bench section.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.aggregators import list_aggregators, make_spec
from repro.core.resilience import estimate_alpha_f
from repro.kernels import pallas_supported

COMPLEXITY = {
    "krum": "O(n^2 d)", "multi_krum": "O(n^2 d)", "m_krum": "O(m n^2 d)",
    "coordinate_median": "O(n d)", "trimmed_mean": "O(n d)",
    "phocas": "O(n d)", "mean_around_median": "O(n d)",
    "geometric_median": "O(n d log^3 1/eps)",
    "median_of_means": "O(nd + fd log^3 1/eps)",
    "mda": "O(C(n,f) + n^2 d)", "cge": "O(n(log n + d))",
    "cgc": "O((n+f)d + n log n)", "bulyan": "O((n-2f)C + nd)",
    "mean": "O(n d)", "zeno": "O(n d)", "rfa": "O(n d iters)",
    "zeno_pp": "O(n d)",
}

IMPLS = ("gather", "fused", "pallas")


def time_spec(spec, g, state=None, iters=20):
    jitted = jax.jit(lambda x: spec.aggregate(x, state=state))
    jitted(g).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        jitted(g).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6      # us


def impl_comparison(ns=(8, 16, 32), ds=(4096, 65536), f=3, iters=20):
    """{rule: {"n{n}_d{d}": {impl: us_per_call}}} for every rule with a
    registered Pallas kernel — the gather/fused/pallas series."""
    key = jax.random.PRNGKey(0)
    rules = [r for r in list_aggregators("table2") if pallas_supported(r)]
    out = {}
    for rule in rules:
        series = {}
        for n in ns:
            fr = min(f, (n - 1) // 2)
            for d in ds:
                g = jax.random.normal(key, (n, d))
                series[f"n{n}_d{d}"] = {
                    impl: round(time_spec(
                        make_spec(rule, f=fr, impl=impl, n=n), g,
                        iters=iters), 1)
                    for impl in IMPLS}
        out[rule] = series
    return out


def run(quick: bool = True):
    rows = []
    n, f = 16, 3
    ds = [4096] if quick else [4096, 65536]
    key = jax.random.PRNGKey(0)
    names = list_aggregators("table2") + ["zeno_pp"]
    for d in ds:
        g = jax.random.normal(key, (n, d))
        for name in names:
            spec = make_spec(name, f=f, n=n)
            state = None
            if spec.stateful:
                # externally-maintained validation gradient (state protocol)
                state = {"server_grad": jnp.mean(g, axis=0)}
            us = time_spec(spec, g, state=state)
            if spec.stateful:
                resilient = True          # validation-gradient rules
            else:
                _, resilient = estimate_alpha_f(spec, n, f,
                                                trials=8 if quick else 32)
            rows.append({
                "bench": "table2_filters", "name": f"{name}_n{n}_d{d}",
                "us_per_call": round(us, 1),
                "derived": (f"complexity={COMPLEXITY.get(name, '-')};"
                            f"impl={spec.impl};"
                            f"alpha_f_ok={resilient}"),
            })
    # the gather/fused/pallas comparison as CSV rows too
    comp = impl_comparison(ns=(16,), ds=tuple(ds), iters=10)
    for rule, series in comp.items():
        for shape, impls in series.items():
            rows.append({
                "bench": "table2_filters",
                "name": f"{rule}_{shape}_impls",
                "us_per_call": impls["pallas"],
                "derived": (f"gather={impls['gather']};"
                            f"fused={impls['fused']};"
                            f"pallas={impls['pallas']}"),
            })
    return rows


def main(out: str = "BENCH_filters.json", full: bool = False):
    ns = (8, 16, 32) if full else (8, 16)
    ds = (4096, 65536, 262144) if full else (4096, 65536)
    comp = impl_comparison(ns=ns, ds=ds)
    payload = {"bench": "filters_impl_comparison",
               "unit": "us_per_call",
               "impls": list(IMPLS),
               "rules": comp}
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
    for rule, series in comp.items():
        for shape, impls in series.items():
            print(f"{rule:20s} {shape:12s} " + "  ".join(
                f"{i}={impls[i]:9.1f}us" for i in IMPLS))
    print(f"wrote {out}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_filters.json")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(args.out, full=args.full)
