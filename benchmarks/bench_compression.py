"""Benchmark 8 — compressed robust exchange (PR 9): wire-bytes, decode
cost and robustness of the quantized arena (int8 / fp8 + per-row scale
sidecar), the 1-bit sign vote, and the sparse masked weighting.

``python benchmarks/bench_compression.py`` writes
``BENCH_compression.json`` (``--smoke`` for the CI lane) with three
sections:

  * wire      — bytes/row of the exchange at the model point (n=16):
                fp32 arena vs sign (1 bit/coordinate), int8 and fp8
                (1 byte/coordinate + one f32 scale per row).  The CI
                lane asserts the sign and int8 ratios (32x / ~4x).
  * latency   — jitted arena-aggregate cost: the f32 path vs
                quantize_rows + the scaled in-tile-dequant kernels vs
                the sign vote on raw codes.
  * training  — final-loss delta vs the uncompressed exchange under the
                large_value attack, through the real async loop (the
                quantized flat pipeline end to end).

``run(quick)`` feeds the ``benchmarks/run.py`` CSV harness with the wire
model and the latency comparison.
"""
from __future__ import annotations

import json
import math
import time

import jax
import jax.numpy as jnp

from repro.core.aggregators import make_spec
from repro.core.flat import QUANT_DTYPES, quantize_rows

N = 16


def _timed(fn, iters=20):
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def wire_rows(p: int):
    """bytes/row of one agent's exchange for a P-coordinate arena."""
    base = 4 * p                                   # fp32, no sidecar
    rows = [{"section": "wire", "name": "fp32", "n": N, "P": p,
             "bytes_per_row": base, "ratio": 1.0}]
    rows.append({"section": "wire", "name": "sign", "n": N, "P": p,
                 "bytes_per_row": math.ceil(p / 8),
                 "ratio": round(base / math.ceil(p / 8), 2)})
    for qdt in sorted(QUANT_DTYPES):
        b = p + 4                                  # 1B codes + f32 scale
        rows.append({"section": "wire", "name": qdt, "n": N, "P": p,
                     "bytes_per_row": b, "ratio": round(base / b, 2)})
    return rows


def latency_rows(p: int, iters: int, seed: int):
    g = jax.random.normal(jax.random.PRNGKey(seed), (N, p)) * 2.0
    spec = make_spec("trimmed_mean", f=2, impl="pallas", n=N)
    sign = make_spec("sign_sgd", f=2, impl="pallas", n=N)
    rows = []

    jf32 = jax.jit(lambda x: spec.aggregate_flat(x))
    rows.append({"section": "latency", "name": "trimmed_mean_fp32",
                 "n": N, "P": p, "us_per_call": round(_timed(
                     lambda: jf32(g).block_until_ready(), iters), 1)})

    for qdt in sorted(QUANT_DTYPES):
        dt = jnp.dtype(qdt)

        @jax.jit
        def jq(x, dt=dt):
            codes, qs = quantize_rows(x, dt)
            return spec.aggregate_flat(codes, scale=qs)

        rows.append({"section": "latency",
                     "name": f"trimmed_mean_{qdt}",
                     "n": N, "P": p, "us_per_call": round(_timed(
                         lambda: jq(g).block_until_ready(), iters), 1),
                     "note": "quantize + in-tile-dequant kernel"})

    jsign = jax.jit(lambda x: sign.aggregate_flat(x))
    rows.append({"section": "latency", "name": "sign_sgd", "n": N, "P": p,
                 "us_per_call": round(_timed(
                     lambda: jsign(g).block_until_ready(), iters), 1),
                 "note": "majority sign vote"})
    return rows


def training_rows(steps: int, seed: int):
    """Final-loss deltas vs the uncompressed exchange under large_value,
    through the async loop's quantized flat pipeline."""
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant
    from repro.simulator import SimConfig, async_train_loop
    from repro.training import ByzantineConfig

    cfg = get_config("paper-100m-smoke").replace(vocab_size=32,
                                                 dtype="float32")
    rows, base_loss = [], None
    cases = [("trimmed_mean_fp32", "trimmed_mean", None),
             ("trimmed_mean_int8", "trimmed_mean", "int8"),
             ("sign_sgd_fp32", "sign_sgd", None)]
    for name, rule, agg_dtype in cases:
        ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=8,
                         per_agent_batch=1)
        bz = ByzantineConfig(n_agents=8, f=2, attack="large_value",
                             aggregator=make_spec(rule, f=2, n=8),
                             agg_dtype=agg_dtype)
        _, h = async_train_loop(cfg, bz, adamw(constant(1e-3)), ds,
                                steps=steps, sim=SimConfig(seed=seed),
                                log_every=steps, log_fn=lambda *_: None)
        loss = float(h[-1]["loss"])
        if base_loss is None:
            base_loss = loss
        rows.append({"section": "training", "name": name, "steps": steps,
                     "attack": "large_value", "final_loss": round(loss, 4),
                     "loss_delta_vs_fp32": round(loss - base_loss, 4)})
    return rows


def run(quick: bool = True):
    p = 2 ** 14 if quick else 2 ** 18
    out = []
    for r in wire_rows(p):
        out.append({"bench": "compression", "name": f"wire_{r['name']}",
                    "us_per_call": 0.0,
                    "derived": (f"bytes_per_row={r['bytes_per_row']};"
                                f"ratio={r['ratio']}x")})
    for r in latency_rows(p, iters=5 if quick else 20, seed=0):
        out.append({"bench": "compression", "name": r["name"],
                    "us_per_call": r["us_per_call"],
                    "derived": r.get("note", "fp32 arena baseline")})
    return out


def main(out: str = "BENCH_compression.json", smoke: bool = False,
         seed: int = 0):
    p = 2 ** 14 if smoke else 2 ** 20
    iters = 5 if smoke else 20
    steps = 12 if smoke else 40
    rows = wire_rows(p) + latency_rows(p, iters, seed) \
        + training_rows(steps, seed)

    from repro.obs.provenance import provenance
    results = {"bench": "compression", "n": N, "P": p, "seed": seed,
               "smoke": bool(smoke), "rows": rows,
               "provenance": provenance()}
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"{'section':<10}{'name':<22}  notes")
    for row in rows:
        notes = "; ".join(f"{k}={v}" for k, v in row.items()
                          if k not in ("section", "name"))
        print(f"{row['section']:<10}{row['name']:<22}  {notes}")
    print(f"wrote {out}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_compression.json")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.out, args.smoke, args.seed)
