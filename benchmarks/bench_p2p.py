"""Benchmark 4 — peer-to-peer fault-tolerant DGD (§3.3.5): final honest-agent
error under Byzantine broadcast, per combine rule and topology."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.p2p import (complete_graph, p2p_dgd_run, ring_graph,
                            torus_graph)


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    n, d, f = 8, 4, 2
    steps = 60 if quick else 200
    targets = 0.2 * jax.random.normal(key, (n, d))
    grad_fn = lambda i, x: x - targets[i]
    x0 = jnp.zeros((n, d)) + 2.0
    byz = jnp.arange(n) < f
    byz_fn = lambda k, t, s: jnp.full_like(s, 50.0)
    hm = jnp.mean(targets[f:], axis=0)
    graphs = {"complete": complete_graph(n), "ring2": ring_graph(n, 2)}
    if not quick:
        graphs["torus"] = torus_graph(2, 4)
    for gname, adj in graphs.items():
        for combine in ("plain", "lf", "ce"):
            t0 = time.perf_counter()
            traj = p2p_dgd_run(adj, grad_fn, x0, steps, f=f, combine=combine,
                               byz_mask=byz, byz_fn=byz_fn)
            wall = time.perf_counter() - t0
            err = float(jnp.max(jnp.linalg.norm(traj[-1][f:] - hm, axis=-1)))
            rows.append({
                "bench": "p2p_dgd", "name": f"{gname}|{combine}",
                "us_per_call": round(wall / steps * 1e6, 1),
                "derived": f"honest_err={err:.4f}",
            })
    return rows
