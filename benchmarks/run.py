"""Benchmark harness — one section per survey table/figure.

  1. table2_filters         — Table 2 (filter catalogue: cost + resilience)
  2. attack_defence_matrix  — convergence under attack (the standard figure)
  3. coding                 — §3.3.3 gradient coding / reactive redundancy
  4. p2p_dgd                — §3.3.5 decentralized fault tolerance
  5. roofline               — §Roofline from the dry-run artifacts
  6. async                  — fault-injection simulator / async training
  7. serving                — continuous-batching replicated-decode scheduler
  8. compression            — compressed robust exchange (sign / int8 / fp8)

Prints ``name,us_per_call,derived`` CSV.  --full for the long versions.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_async, bench_coding, bench_compression,
                            bench_convergence, bench_filters, bench_p2p,
                            bench_roofline, bench_serving)
    benches = {
        "table2_filters": bench_filters.run,
        "attack_defence_matrix": bench_convergence.run,
        "coding": bench_coding.run,
        "p2p_dgd": bench_p2p.run,
        "roofline": bench_roofline.run,
        "async": bench_async.run,
        "serving": bench_serving.run,
        "compression": bench_compression.run,
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for bname, fn in benches.items():
        if only and bname not in only:
            continue
        try:
            rows = fn(quick=quick)
        except Exception as e:              # keep the harness running
            print(f"{bname}/HARNESS_ERROR,-1,{repr(e)[:120]}")
            failures += 1
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['bench']}/{r['name']},{r['us_per_call']},{derived}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
