"""Benchmark — the serving control plane under load and faults.

Sweeps the continuous-batching scheduler (:mod:`repro.serving.sched`)
over offered load x replica fault rate x commit policy and reports the
SLO quantities the early-commit design targets: throughput, p50/p95
token latency and TTFT on the VIRTUAL clock (one clean replica decode =
1.0 vs), plus the realized early-commit fraction.  Everything is seed-
deterministic — workload (Poisson arrivals), replica step delays
(straggler jitter) and the corruption schedule all derive from the run
seed — so two machines produce the same JSON modulo provenance.

The headline comparison: with straggling replicas, ``early`` commits a
token at the (f+1)-th consistent arrival while ``full`` waits for the
slowest live replica — same tokens (bit-identical; pinned by
tests/test_serving_chaos.py), different tail.

``python benchmarks/bench_serving.py`` writes ``BENCH_serving.json``
(``--smoke`` for the CI lane's 1-rate grid); ``run(quick)`` feeds the
``benchmarks/run.py`` CSV harness.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.aggregators import make_spec
from repro.serving.sched import ReplicatedScheduler, poisson_requests

R, F = 5, 2
DELAY_STEPS = 64          # precomputed jitter horizon (cycled)


def _bench_cfg():
    return get_config("paper-100m-smoke").replace(vocab_size=64, d_model=32,
                                                  d_ff=64, num_layers=2)


def _stack(cfg, seed=1):
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(seed))
    return jax.tree.map(lambda l: jnp.stack([l] * R), params)


def _delays(seed: int, straggle: float = 2.5):
    """(DELAY_STEPS, R) per-step replica latencies: 1.0 base + exponential
    jitter, replica 0 a recurring heavy straggler — the regime where the
    early/full gap is visible."""
    rng = np.random.default_rng(seed)
    d = 1.0 + rng.exponential(0.25, size=(DELAY_STEPS, R))
    d[::3, 0] += straggle
    return d


def _fault_hook(fault_rate: float, seed: int):
    """Corrupt replicas {3, 4} (== f) independently per step with
    probability ``fault_rate`` — hostile logits, same corruption the
    chaos suite uses."""
    if fault_rate <= 0:
        return None, np.zeros((DELAY_STEPS, R), bool)
    rng = np.random.default_rng(seed + 17)
    rows = np.zeros((DELAY_STEPS, R), bool)
    rows[:, 3:] = rng.random((DELAY_STEPS, 2)) < fault_rate

    def hook(step, logits):
        sel = jnp.asarray(rows[step % DELAY_STEPS])[:, None, None]
        return jnp.where(sel, -7.0 * logits + 3.0, logits)
    return hook, rows


def bench_point(rate: float, fault_rate: float, early: bool,
                n_requests: int, seed: int = 0, deadline: float = 3.0):
    """One grid point: scheduler drain of a Poisson workload."""
    cfg = _bench_cfg()
    stack = _stack(cfg)
    spec = make_spec("coordinate_median", f=F, n=R)
    hook, _ = _fault_hook(fault_rate, seed)
    delays = _delays(seed)
    reqs = poisson_requests(rate, n_requests / max(rate, 1e-9), seed=seed,
                            vocab_size=cfg.vocab_size, prompt_lens=(4, 8),
                            new_tokens=(3, 4, 6), max_requests=n_requests)
    sched = ReplicatedScheduler(
        cfg, stack, spec, slot_buckets=(2, 4, 8), seq_capacity=16,
        early_commit=early, deadline=deadline if early else None,
        fault_hook=hook, delays=lambda s: delays[s % DELAY_STEPS])
    sched.submit_all(reqs)
    t0 = time.perf_counter()
    metrics = sched.run()
    wall = time.perf_counter() - t0
    out = {"rate": rate, "fault_rate": fault_rate,
           "early_commit": early, "requests": len(reqs),
           "steps": sched.step_idx,
           "wall_s": round(wall, 3),
           "wall_us_per_token": round(
               wall * 1e6 / max(metrics.committed_tokens, 1), 1)}
    out.update(metrics.summary())
    return out


def sweep(rates, fault_rates, n_requests: int, seed: int = 0):
    grid = []
    for rate in rates:
        for p in fault_rates:
            for early in (True, False):
                grid.append(bench_point(rate, p, early, n_requests,
                                        seed=seed))
    return grid


def run(quick: bool = True):
    """run.py harness entry point: CSV rows."""
    rates = (0.6,) if quick else (0.3, 0.6, 1.2)
    fault_rates = (0.0, 0.3)
    grid = sweep(rates, fault_rates, n_requests=8 if quick else 24)
    rows = []
    for g in grid:
        mode = "early" if g["early_commit"] else "full"
        rows.append({
            "bench": "serving",
            "name": f"rate{g['rate']}|p{g['fault_rate']}|{mode}",
            "us_per_call": g["wall_us_per_token"],
            "derived": (f"thru={g['throughput_tokens_per_vsec']:.2f}/vs "
                        f"p95={g['token_latency_p95']:.2f} "
                        f"early={g['early_commit_fraction']:.2f}"),
        })
    return rows


def main(out: str = "BENCH_serving.json", smoke: bool = False,
         seed: int = 0):
    rates = (0.6,) if smoke else (0.3, 0.6, 1.2)
    fault_rates = (0.0, 0.3)
    n_requests = 8 if smoke else 24
    grid = sweep(rates, fault_rates, n_requests, seed=seed)
    from repro.obs.provenance import provenance
    results = {"bench": "serving", "replicas": R, "f": F,
               "aggregator": "coordinate_median", "seed": seed,
               "smoke": bool(smoke), "grid": grid,
               "provenance": provenance()}
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print("rate  fault  mode   thru/vs  tok_p50  tok_p95  ttft_p95  early%")
    for g in grid:
        mode = "early" if g["early_commit"] else "full"
        print(f"{g['rate']:<5} {g['fault_rate']:<6} {mode:<6}"
              f"{g['throughput_tokens_per_vsec']:8.2f}"
              f"{g['token_latency_p50']:9.2f}{g['token_latency_p95']:9.2f}"
              f"{g['ttft_p95']:10.2f}"
              f"{100 * g['early_commit_fraction']:7.1f}")
    print(f"wrote {out}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.out, args.smoke, args.seed)
